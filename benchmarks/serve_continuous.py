"""Continuous-batching serving benchmark (DESIGN.md section 10).

Two phases, one report (``BENCH_continuous.json``):

**Phase A — prefill-strategy comparison (closed loop).** The same decode
work served three ways, best-of-``--repeats`` wall time each:

  * ``packed``     — mixed-length prompts through the packed-prefill
                     engine: ONE ``[1, bucket]`` dispatch admits them all
                     (segment-masked attention, scatter-merge into slots).
  * ``batched``    — same-token-count prompts of EQUAL length through the
                     grouped engine: its best case, one ``[N, L]`` dispatch.
  * ``sequential`` — the same mixed-length prompts through the grouped
                     engine: every length is distinct, so admission
                     degenerates to one prefill dispatch per prompt.

  Expected ordering: packed >= batched (packed pays segment masking but
  skips nothing else) and packed > sequential (N dispatches vs 1).

**Phase B — bursty open loop.** Arrivals come from the two-state MMPP in
``benchmarks/traffic_o1.py`` (``bursty_arrivals`` — the generator the
ROADMAP flagged as unused by the serving stack), offered at ``--load`` x
the measured closed-loop capacity. A slice of requests carries QoS
deadlines (exercising mid-generation cancellation), and the steady-state
invariant is asserted: **zero retraces** — every program the serving path
runs was AOT-compiled at ``warmup()``.

  PYTHONPATH=src python benchmarks/serve_continuous.py --smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python benchmarks/serve_continuous.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from traffic_o1 import bursty_arrivals
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp


def _mixed_lengths(n: int, lo: int, hi: int) -> list:
    """n distinct-ish prompt lengths spread over [lo, hi] (distinct lengths
    force the grouped engine into per-prompt prefill dispatches)."""
    return [int(x) for x in np.linspace(lo, hi, n).round()]


def _requests(cfg, lengths, new_tokens, seed=0, uid0=0):
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(uid=uid0 + i,
                prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=new_tokens)
        for i, L in enumerate(lengths)
    ]


def _serve_closed(engine, make_reqs, repeats: int):
    """Best-of-``repeats`` closed-loop serve: submit everything, drain,
    count generated tokens. The first (untimed) pass plus ``warmup()``
    keep every compile out of the measured passes."""
    engine.warmup()
    for r in make_reqs():  # untimed pass: any residual compile happens here
        engine.submit(r)
    engine.run_until_drained()
    best_dt, toks = float("inf"), 0
    for _ in range(repeats):
        reqs = make_reqs()
        t0 = time.perf_counter()
        for r in reqs:
            engine.submit(r)
        engine.run_until_drained()
        dt = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in reqs)
        assert all(len(r.generated) == r.max_new_tokens for r in reqs)
        best_dt = min(best_dt, dt)
    return {"tok_s": toks / best_dt, "wall_s": best_dt, "tokens": toks,
            "req_s": len(reqs) / best_dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_continuous.json")
    ap.add_argument("--requests", type=int, default=0,
                    help="phase-A requests (0 = batch_slots x 4)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--load", type=float, default=0.7,
                    help="phase-B offered load as a fraction of measured "
                         "closed-loop capacity")
    ap.add_argument("--open-requests", type=int, default=0,
                    help="phase-B request count (0 = 3x phase A)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    import repro.models as M
    from repro.configs import get_config, smoke_config
    from repro.serving.engine import ServeEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(remat=False)
    if cfg.attn is None:
        raise SystemExit(f"{args.arch}: packed prefill needs an attention "
                         "family (ssm/hybrid archs keep the grouped path)")
    params = M.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    n = args.requests or args.slots * 4
    lo, hi = 8, max(10, args.max_len // 4)
    mixed = _mixed_lengths(n, lo, hi)
    same = [int(round(sum(mixed) / n))] * n  # equal token count, equal length
    grouped_cfg = cfg.replace(serve=dataclasses.replace(
        cfg.serve, packed_prefill=False))
    print(f"arch={cfg.name} devices={jax.device_count()} requests={n} "
          f"prompt lengths {lo}..{hi} (sum {sum(mixed)}), "
          f"new_tokens={args.new_tokens}")

    # -- phase A: closed-loop prefill-strategy comparison --------------------
    scenarios = {}
    for name, scfg, lengths in (
        ("packed", cfg, mixed),
        ("batched", grouped_cfg, same),
        ("sequential", grouped_cfg, mixed),
    ):
        eng = ServeEngine(scfg, params, batch_slots=args.slots,
                          max_len=args.max_len)
        if name == "packed":
            assert eng._packed, "packed path must engage for this family"
        make = lambda L=lengths: _requests(cfg, L, args.new_tokens,
                                           seed=args.seed)
        scenarios[name] = _serve_closed(eng, make, args.repeats)
        scenarios[name]["counters"] = dict(eng.metrics.counters)
        print(f"  {name:>10s}: {scenarios[name]['tok_s']:8.1f} tok/s "
              f"({scenarios[name]['wall_s'] * 1e3:.0f} ms, "
              f"{scenarios[name]['counters'].get('prefill_batches', 0)} "
              f"prefill dispatches)")

    # -- phase B: bursty open loop through the packed engine -----------------
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_len=args.max_len)
    eng.warmup()
    n_open = args.open_requests or 3 * n
    cap_rps = scenarios["packed"]["req_s"]
    rate = max(1e-3, args.load * cap_rps)
    sched = bursty_arrivals(n_open / rate, rate, seed=args.seed)
    lengths = [mixed[i % len(mixed)] for i in range(len(sched))]
    reqs = _requests(cfg, lengths, args.new_tokens, seed=args.seed + 1)
    done = []
    # deadline slice: generous enough that an uncongested request finishes,
    # tight enough that burst-tail queueing cancels some — both branches of
    # the cancellation path run under real load
    deadline_s = 8.0 / max(cap_rps, 1e-3)
    for i, r in enumerate(reqs):
        r.on_done = done.append
        if i % 8 == 3:
            r.deadline = deadline_s
    retr0 = eng.metrics.counters.get("retraces", 0)
    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or not eng.idle:
        now = time.perf_counter() - t0
        while i < len(reqs) and sched[i] <= now:
            eng.submit(reqs[i])
            i += 1
        eng.step()
    eng.flush()
    wall = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    c = snap["counters"]
    retraces = c.get("retraces", 0) - retr0
    real = c.get("pack_real_tokens", 0)
    pad = c.get("pack_pad_tokens", 0)
    util = real / (real + pad) if real + pad else float("nan")
    open_phase = {
        "requests": len(reqs),
        "offered_rps": rate,
        "tok_s": c.get("tokens", 0) / wall,
        "wall_s": wall,
        "completed": c.get("completed", 0),
        "cancelled": c.get("cancelled", 0),
        "callbacks_fired": len(done),
        "retraces": int(retraces),
        "prefill_batches": c.get("prefill_batches", 0),
        "pack_real_tokens": int(real),
        "pack_pad_tokens": int(pad),
        "pack_utilization": util,
        "latency_ms": snap["latency_ms"],
        "queue_wait_ms": snap["queue_wait_ms"],
    }
    print(f"  open loop: {open_phase['tok_s']:.1f} tok/s at "
          f"{rate:.1f} req/s offered, completed={open_phase['completed']} "
          f"cancelled={open_phase['cancelled']} retraces={retraces} "
          f"pack utilization {100 * util:.1f}%")

    checks = {
        # mixed-length packed admission must keep up with the grouped
        # engine's best case (equal lengths, one batched dispatch)
        "packed_ge_batched":
            scenarios["packed"]["tok_s"] >= scenarios["batched"]["tok_s"],
        # and clearly beat per-prompt sequential prefill
        "packed_gt_sequential":
            scenarios["packed"]["tok_s"] > scenarios["sequential"]["tok_s"],
        # steady state never compiles: every serving program came out of
        # the warmup()-populated AOT cache
        "retraces_zero": retraces == 0,
        "all_retired": (open_phase["completed"] + open_phase["cancelled"]
                        == len(reqs)),
    }
    for name, ok in checks.items():
        print(f"  [{'ok' if ok else 'MISS'}] {name}")

    report = {
        "meta": {
            "bench": "serve_continuous",
            "mode": "smoke" if args.smoke else "full",
            "arch": cfg.name,
            "devices": jax.device_count(),
            "requests": n,
            "new_tokens": args.new_tokens,
            "prompt_lengths": mixed,
            "repeats": args.repeats,
        },
        "closed_loop": scenarios,
        "open_loop": open_phase,
        "checks": checks,
        "fps": scenarios["packed"]["tok_s"],
    }
    stamp(report, "serve_continuous")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
