"""Benchmark aggregator: one section per paper table/figure plus the
roofline and O(1)-traffic analyses. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # fast set
  PYTHONPATH=src python -m benchmarks.run --full     # + brief PTQ training
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the PTQ fidelity benchmark (trains small "
                         "models; several minutes on CPU)")
    args = ap.parse_args()

    print("name,us_per_call,derived")

    t0 = time.perf_counter()
    from benchmarks import traffic_o1

    traffic_o1.run(csv=True)

    from benchmarks import table34_throughput

    table34_throughput.run(csv=True, measure=True,
                           archs=["vit-tiny", "m3vit-tiny"])

    try:
        from benchmarks import roofline

        rows = roofline.run(csv=True)
        if not rows:
            print("roofline,0,no_dryrun_artifacts_found")
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"roofline,0,error={e!r}")

    if args.full:
        from benchmarks import table1_quant_fidelity

        table1_quant_fidelity.run(csv=True, train_steps=40)

    dt = time.perf_counter() - t0
    print(f"benchmarks_total,{dt*1e6:.0f},sections="
          f"{'4' if args.full else '3'}")


if __name__ == "__main__":
    main()
