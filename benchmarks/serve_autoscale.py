"""Autoscaling admission benchmark: offered-load ramp -> replica-count
trace (DESIGN.md section 8; the ROADMAP "Autoscaling admission" item made
measurable).

Drives an open-loop arrival process through ``ServingCluster`` +
``Autoscaler`` in three phases — low, surge (past one replica's measured
capacity), low — and samples a trace of (t, active replicas, standby,
draining, front depth, windowed p95). The expected shape, asserted softly
and written to ``BENCH_autoscale.json``:

  * the replica count **rises** during the surge (pre-warmed standbys
    promoted into the router) and **falls back** in the final low phase
    (replicas drained to standby);
  * pooled p95 latency returns under the SLO after scale-up;
  * **no request is lost**: every submitted request completes, including
    the ones in flight on replicas that drain mid-run.

Single-replica capacity is measured first (closed-loop burst on a
throwaway engine), so the surge rate adapts to the machine — the trace
shape is load-real even though all replicas share one CPU.

  PYTHONPATH=src python benchmarks/serve_autoscale.py --smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python benchmarks/serve_autoscale.py --smoke
"""
from __future__ import annotations

import argparse
import json
import time
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp


def measure_single_replica_fps(cfg, params, bucket: int, n: int) -> float:
    """Closed-loop FPS of one replica (throwaway engine: keeps the
    measurement out of the cluster's metrics)."""
    from repro.serving.vision import VisionEngine, synth_requests

    eng = VisionEngine(cfg, params, batch_buckets=(bucket,), max_wait_s=0.0)
    eng.warmup()
    reqs = synth_requests(cfg, n, seed=99)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
        eng.step()
    eng.flush()
    return n / (time.perf_counter() - t0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="m3vit-tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke config + short phases (CI)")
    ap.add_argument("--out", default="BENCH_autoscale.json")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="p95 SLO; 0 = auto (8x the closed-loop batch time)")
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--phase-s", type=float, default=0.0,
                    help="surge-phase duration; 0 = 2.5s (smoke) / 6s")
    args = ap.parse_args()

    import jax

    import repro.models as M
    from repro.configs import PAPER_ARCHS, AutoscaleConfig, smoke_config
    from repro.serving.autoscaler import Autoscaler
    from repro.serving.cluster import ServingCluster
    from repro.serving.vision import synth_requests

    if args.smoke:
        cfg = smoke_config(args.arch).replace(remat=False)
        bucket, est_n = 2, 16
    else:
        cfg = PAPER_ARCHS[args.arch].replace(remat=False)
        bucket, est_n = 4, 64
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))

    cap_fps = measure_single_replica_fps(cfg, params, bucket, est_n)
    slo_ms = args.slo_ms or max(50.0, 8e3 * bucket / cap_fps)
    surge_s = args.phase_s or (2.5 if args.smoke else 6.0)
    # surge past one replica's capacity, but not past the fleet's: on a
    # shared-compute box an unbounded 2.5x overload just builds a backlog
    # no amount of scale-up can absorb — the interesting regime is the one
    # where added replicas actually clear the queue
    phases = [  # (duration_s, offered rate in requests/s)
        ("low", surge_s * 0.6, 0.4 * cap_fps),
        ("surge", surge_s, 1.6 * cap_fps),
        ("low", surge_s * 1.6, 0.15 * cap_fps),
    ]
    print(f"arch={cfg.name} devices={jax.device_count()} "
          f"single-replica capacity ~{cap_fps:.1f} FPS, SLO p95 {slo_ms:.0f}ms")

    # the controller is evaluated on a fixed wall-clock cadence (the pump
    # spins much faster), so patience/cooldown/TTL counts mean stable
    # wall-time amounts regardless of how hot the serving loop runs
    tick_every = 0.005
    policy = AutoscaleConfig(
        min_replicas=1, max_replicas=args.max_replicas,
        standby=args.max_replicas - 1,
        slo_p95_ms=slo_ms, depth_high=2.0 * bucket, up_patience=2,
        depth_low=0.0, down_patience=60, cooldown=40,
        min_window_samples=8, p95_ttl=200,
    )
    cluster = ServingCluster(
        cfg, params, replicas=policy.min_replicas, standby=policy.standby,
        batch_buckets=(1, bucket), max_wait_s=1e-3,
        max_pending=0, max_pending_per_replica=2 * bucket,
        clock=time.perf_counter,  # one clock for trace, timeline, events
    )
    cluster.warmup()
    scaler = Autoscaler(cluster, policy)

    # open-loop arrival schedule
    arrivals = []
    t = 0.0
    for _, dur, rate in phases:
        end = t + dur
        while t < end:
            arrivals.append(t)
            t += 1.0 / rate
    reqs = synth_requests(cfg, len(arrivals), seed=0)

    trace = []
    sample_every = 0.05
    t0 = time.perf_counter()
    next_sample = 0.0
    next_tick = 0.0
    i = 0

    def pump(now: float) -> None:
        nonlocal next_tick, next_sample
        cluster.step()
        if now >= next_tick:
            scaler.tick()
            next_tick = now + tick_every
        if now >= next_sample:
            s = scaler.state()
            s["t"] = round(now, 4)
            trace.append(s)
            next_sample = now + sample_every

    while i < len(arrivals) or not cluster.idle:
        now = time.perf_counter() - t0
        while i < len(arrivals) and arrivals[i] <= now:
            cluster.submit(reqs[i])
            i += 1
        pump(now)
    cluster.flush()
    # post-ramp cooldown: keep ticking so the controller drains back down
    deadline = time.perf_counter() - t0 + 3 * surge_s
    while (cluster.num_replicas > policy.min_replicas
           and time.perf_counter() - t0 < deadline):
        pump(time.perf_counter() - t0)
    wall = time.perf_counter() - t0
    final = scaler.state()
    final["t"] = round(wall, 4)
    trace.append(final)

    assert all(r.done for r in reqs), "requests lost across the ramp/drain"
    snap = cluster.metrics.snapshot()
    agg = snap["aggregate"]
    counts = [row["replicas"] for row in trace]
    peak = max(counts)
    first_peak = counts.index(peak)
    # windowed p95 samples after the fleet reached peak size: scale-up is
    # "working" if latency recovers under the SLO at some point (the surge
    # backlog takes a few windows to clear; "the last sample" would be
    # hostage to scheduling noise on a shared box)
    post_peak_p95 = [row["p95_ms"] for row in trace[first_peak:]
                     if row["p95_ms"] == row["p95_ms"]]
    checks = {
        "replicas_rose": peak > policy.min_replicas,
        "replicas_fell_back": counts[-1] == policy.min_replicas,
        "p95_under_slo_after_scale_up": bool(
            post_peak_p95 and min(post_peak_p95) <= slo_ms),
        "no_request_lost": agg["counters"]["completed"] == len(reqs),
    }
    for name, ok in checks.items():
        print(f"  [{'ok' if ok else 'MISS'}] {name}")
    print(f"replica count: start=1 peak={peak} end={counts[-1]}  "
          f"fps={agg['fps']:.1f}  p95={agg['latency_ms']['p95']:.1f}ms  "
          f"completed={agg['counters']['completed']}/{len(reqs)}")

    report = {
        "meta": {
            "bench": "serve_autoscale",
            "mode": "smoke" if args.smoke else "full",
            "arch": cfg.name,
            "devices": jax.device_count(),
            "single_replica_fps": cap_fps,
            "slo_p95_ms": slo_ms,
            "phases": [{"name": n, "duration_s": d, "rate_rps": r}
                       for n, d, r in phases],
            "wall_s": wall,
            "note": ("CPU-host run: all replicas share compute, so the "
                     "trace shows controller behavior under real load, "
                     "not hardware speedup"),
        },
        "policy": {k: getattr(policy, k) for k in (
            "min_replicas", "max_replicas", "standby", "slo_p95_ms",
            "depth_high", "up_patience", "depth_low", "down_patience",
            "cooldown", "min_window_samples")},
        "checks": checks,
        # cluster clock is perf_counter; report times relative to ramp start
        "scale_events": [
            {"t": round(t - t0, 4), "action": a, "replicas": n}
            for t, a, n in scaler.events
        ],
        "trace": trace,
        "replica_timeline": [[round(t - t0, 4), n]
                             for t, n in snap["replica_timeline"]],
        "aggregate": agg,
        "fps": agg["fps"],
    }
    stamp(report, "serve_autoscale")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out} ({len(trace)} trace samples, "
          f"{len(scaler.events)} scale events)")


if __name__ == "__main__":
    main()
