"""The paper's O(1) off-chip-traffic claims, verified structurally on the
TPU kernels (DESIGN.md section 2 maps "PE count" -> tile-parallel width):

(a) Unified linear kernel (section 4.2b): each expert's weights cross
    HBM->VMEM once per (expert, n-tile) pair — independent of the token
    count T. Computed exactly from the kernel's routing metadata (the same
    index maps the hardware walks). The naive per-token baseline refetches
    the expert weight for every token tile.

(b) Streaming attention (section 4.2a): K/V HBM traffic per Q tile is
    constant; widening the per-tile parallelism (block_q — the PE-array
    width analogue) *reduces* total K re-streams as O(Sq / block_q), with
    the limit block_q = Sq giving exactly one K stream (the FPGA broadcast).

This module also owns the serving stack's offered-load generator
(``bursty_arrivals``): a two-state Markov-modulated Poisson process that
``benchmarks/serve_continuous.py`` drives the continuous-batching engine
with — traffic modeling lives with the traffic analysis.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.expert_linear import _route_metadata


def bursty_arrivals(
    duration_s: float,
    rate_rps: float,
    *,
    burst_factor: float = 4.0,
    burst_fraction: float = 0.25,
    mean_phase_s: float = 0.5,
    seed: int = 0,
) -> np.ndarray:
    """Bursty open-loop arrival offsets (seconds, sorted, in [0, duration)).

    A two-state MMPP: the process alternates between a *calm* phase and a
    *burst* phase (exponential phase lengths, mean ``mean_phase_s``).
    Inter-arrivals within a phase are exponential at the phase rate; rates
    are chosen so the long-run average is ``rate_rps`` while bursts run at
    ``burst_factor`` times the calm rate — the arrival pattern dynamic
    batching exists for (uniform pacing never exercises pack formation).

    Deterministic for a given seed, so benchmark runs are reproducible.
    """
    if rate_rps <= 0 or duration_s <= 0:
        return np.zeros(0, np.float64)
    bf, frac = max(1.0, burst_factor), min(max(burst_fraction, 0.0), 1.0)
    # solve calm/burst rates: frac of time in burst at bf*calm_rate, mean
    # over both phases equals rate_rps
    calm_rate = rate_rps / (1.0 - frac + frac * bf)
    burst_rate = bf * calm_rate
    rng = np.random.default_rng(seed)
    out, t, burst = [], 0.0, False
    while t < duration_s:
        phase_mean = mean_phase_s * (frac if burst else (1.0 - frac)) * 2.0
        phase_end = min(duration_s, t + rng.exponential(max(phase_mean, 1e-6)))
        rate = burst_rate if burst else calm_rate
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= phase_end:
                t = phase_end
                break
            out.append(t)
        burst = not burst
    return np.asarray(out, np.float64)


def weight_traffic_bytes(T: int, G: int, Din: int, Dout: int,
                         block_m: int = 128, block_n: int = 128,
                         bytes_per: int = 1) -> tuple:
    """(kernel HBM weight bytes, naive per-tile-refetch bytes)."""
    rng = np.random.default_rng(0)
    # balanced-ish random routing
    sizes = rng.multinomial(T, np.ones(G) / G)
    n_m = -(-T // block_m)
    n_work = n_m + G
    g_ids, m_ids, rs, re = _route_metadata(
        jnp.asarray(sizes, jnp.int32), block_m, n_work)
    g_ids = np.asarray(g_ids)
    active = np.asarray(re) > np.asarray(rs)
    n_n = -(-Dout // block_n)
    # kernel: distinct (g, n) fetches — the index map re-fetches w tile only
    # when (g) changes per n; consecutive same-g visits reuse VMEM residency
    fetches = 0
    for n in range(n_n):
        last_g = -1
        for w in range(n_work):
            if not active[w]:
                continue
            if g_ids[w] != last_g:
                fetches += 1
                last_g = g_ids[w]
    tile_bytes = Din * block_n * bytes_per
    kernel_bytes = fetches * tile_bytes
    # naive: every m-tile re-fetches its expert's weight tile
    naive_bytes = int(active.sum()) * n_n * tile_bytes
    return kernel_bytes, naive_bytes


def attention_k_traffic(Sq: int, Sk: int, hd: int, block_q: int,
                        bytes_per: int = 2) -> int:
    """K bytes streamed from HBM for one (batch, head): nq passes over K."""
    nq = -(-Sq // block_q)
    return nq * Sk * hd * bytes_per


def run(csv=False):
    rows = []
    G, Din, Dout = 64, 2048, 1024
    base_kernel = None
    for T in (512, 2048, 8192, 32768):
        kb, nb = weight_traffic_bytes(T, G, Din, Dout)
        if base_kernel is None:
            base_kernel = kb
        rows.append(("expert_weights", T, kb, nb))
    ratio = rows[-1][2] / base_kernel
    if not csv:
        print("(a) unified linear kernel — expert weight HBM bytes vs tokens")
        print(f"{'tokens':>8s} {'kernel bytes':>14s} {'naive bytes':>14s}")
        for _, T, kb, nb in rows:
            print(f"{T:8d} {kb:14d} {nb:14d}")
        print(f"  kernel traffic grows {ratio:.2f}x over a 64x token increase "
              f"(naive: {rows[-1][3] / rows[0][3]:.1f}x) — O(1) in T per "
              f"(expert, n-tile)\n")

    att = []
    Sq = Sk = 4096
    for bq in (128, 256, 512, 1024, 4096):
        att.append((bq, attention_k_traffic(Sq, Sk, 128, bq)))
    if not csv:
        print("(b) streaming attention — K HBM bytes vs Q-tile width "
              f"(Sq=Sk={Sq}, one head)")
        print(f"{'block_q':>8s} {'K bytes':>14s}")
        for bq, b in att:
            print(f"{bq:8d} {b:14d}")
        print("  limit block_q=Sq: exactly one K stream (the FPGA broadcast)")
    if csv:
        print(f"traffic_o1_expert,0,growth_64x_tokens={ratio:.3f}")
        print(f"traffic_o1_attn,0,k_bytes_ratio_bq128_to_full="
              f"{att[0][1] / att[-1][1]:.1f}")
    return {"expert_rows": rows, "attn_rows": att}


if __name__ == "__main__":
    run()
