"""Paper Tables 3-4 proxy: end-to-end quantized-model throughput.

The FPGA numbers (GOPS, latency, power) are platform-bound; the honest
TPU-side equivalents we can produce are:

  * analytic GOP/image for each arch (2 x MACs, matching the paper's
    convention),
  * measured wall-clock of the jitted quantized forward on this host (CPU —
    a lower bound sanity check that the quantized graph is real), and
  * a single-chip TPU-v5e roofline projection: time/image =
    max(FLOPs / peak, bytes / HBM_bw) from the model's analytic compute and
    weight/activation traffic at batch 4 (the paper's batch).

Reported per arch with the paper's own Table 3/4 rows for context.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import PAPER_ARCHS, get_shape
from benchmarks import hw

BATCH = 4  # paper's batch size


def model_gops(cfg) -> float:
    """Analytic GOP per image (2 x MAC count), ViT conventions."""
    N = cfg.image_tokens
    d = cfg.d_model
    a = cfg.attn
    per_layer = 0
    per_layer += 2 * N * d * (a.q_dim + 2 * a.kv_dim)  # qkv proj
    per_layer += 2 * N * N * a.q_dim * 2  # QK^T and PV
    per_layer += 2 * N * a.q_dim * d  # out proj
    mlp = 2 * N * d * cfg.d_ff * 2  # fc1 + fc2 (d_ff = 4d)
    n_moe = 0
    if cfg.moe is not None:
        n_moe = cfg.num_layers // 2
        moe_flops = 2 * N * d * cfg.moe.d_ff * 2 * cfg.moe.top_k
        total = (cfg.num_layers - n_moe) * (per_layer + mlp) \
            + n_moe * (per_layer + moe_flops)
    else:
        total = cfg.num_layers * (per_layer + mlp)
    total += 2 * N * 768 * d  # patch proj
    total += 2 * d * cfg.num_classes
    return total / 1e9


def model_weight_bytes(cfg, int8=True) -> float:
    per = 1 if int8 else 2
    return cfg.active_param_count() * per


def tpu_projection_ms(cfg) -> float:
    """Single-v5e-chip roofline latency per image at batch=4 (INT8 path)."""
    flops = model_gops(cfg) * 1e9 * BATCH
    compute_s = flops / hw.PEAK_FLOPS_INT8
    # weights stream once per batch (the paper's pre-load/temporal-locality
    # property); activations ~ 2 x per layer boundary
    act_bytes = BATCH * cfg.image_tokens * cfg.d_model * 2 * cfg.num_layers * 4
    mem_s = (model_weight_bytes(cfg) + act_bytes) / hw.HBM_BW
    return max(compute_s, mem_s) / BATCH * 1e3


def measured_cpu_ms(cfg, params, n=3) -> float:
    shape = get_shape("train_4k").replace(global_batch=BATCH)
    batch = M.synth_batch(cfg, shape, jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, b: M.forward(p, cfg, b)[0])
    fwd(params, batch).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        fwd(params, batch).block_until_ready()
    return (time.perf_counter() - t0) / n / BATCH * 1e3


PAPER_ROWS = {  # (platform, GOPS, ms, W) from paper Tables 3-4
    "m3vit-tiny": ("CoQMoE-ZCU102", 386.3, 6.47, 9.83),
    "m3vit-small": ("CoQMoE-U280", 1004.3, 9.16, 33.7),
    "vit-tiny": ("CoQMoE-E ZCU102", 452.08, 5.53, 9.83),
    "vit-small": ("CoQMoE-C U280", 1345.0, 6.84, 33.7),
}


def run(csv=False, measure=True, archs=None):
    rows = []
    for arch in archs or ["vit-tiny", "vit-small", "m3vit-tiny", "m3vit-small"]:
        cfg = PAPER_ARCHS[arch].replace(remat=False)
        gop = model_gops(cfg)
        proj_ms = tpu_projection_ms(cfg)
        cpu_ms = float("nan")
        if measure:
            params = M.init_model_params(cfg, jax.random.PRNGKey(0),
                                         jnp.float32)
            cpu_ms = measured_cpu_ms(cfg, params)
        proj_gops = gop / (proj_ms / 1e3)
        rows.append({"arch": arch, "gop_per_img": gop,
                     "cpu_ms_per_img": cpu_ms,
                     "v5e_proj_ms_per_img": proj_ms,
                     "v5e_proj_gops": proj_gops,
                     "paper": PAPER_ROWS.get(arch)})
    if csv:
        for r in rows:
            print(f"table34_{r['arch']},{r['cpu_ms_per_img']*1e3:.0f},"
                  f"gop={r['gop_per_img']:.2f};v5e_ms={r['v5e_proj_ms_per_img']:.3f};"
                  f"v5e_gops={r['v5e_proj_gops']:.0f}")
    else:
        print(f"{'arch':14s} {'GOP/img':>8s} {'CPU ms':>8s} "
              f"{'v5e ms(proj)':>12s} {'v5e GOPS(proj)':>14s}   paper (plat, GOPS, ms, W)")
        for r in rows:
            print(f"{r['arch']:14s} {r['gop_per_img']:8.2f} "
                  f"{r['cpu_ms_per_img']:8.1f} {r['v5e_proj_ms_per_img']:12.3f} "
                  f"{r['v5e_proj_gops']:14.0f}   {r['paper']}")
    return rows


if __name__ == "__main__":
    run()
