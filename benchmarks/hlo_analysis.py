"""Call-graph-aware optimized-HLO analysis.

Compatibility shim: the analyzer moved to ``repro.analysis.hlo`` so the
serving introspection layer can import it as an installed package.  The
docstring, semantics, and public names (``parse``, ``analyze``) are
unchanged — see that module.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.hlo import analyze, parse  # noqa: F401,E402
