"""Chaos-injection serving benchmark: fault tolerance made measurable
(DESIGN.md section 14) — writes ``BENCH_chaos.json``.

Drives an open-loop offered load through a vision ``ServingCluster`` three
ways:

  baseline — no faults, watchdog on (the production configuration). Sets
             the FPS/p99 reference.
  chaos    — the same load with a scheduled replica kill at steady state
             (``FaultConfig.kill_schedule``, kind ``"dead"`` — every later
             step raises, modelling a crashed process). The watchdog must
             evict the dead replica, the standby must backfill, stranded
             in-flight requests must re-dispatch, and the cluster must
             recover to the baseline completion rate.
  off/on/off — closed-loop overhead passes with the watchdog disabled /
             enabled / disabled again on identical single-replica
             clusters. The off/off2 spread is the measurement noise
             floor; the fault layer must cost <= ``--bound`` beyond it.

Hard checks (exit 1 on failure):

  * **zero lost accepted requests** — every request the cluster accepted
    gets exactly one terminal callback (completed / cancelled / failed),
    counted through ``on_done`` across the eviction;
  * the kill actually evicted a replica and the standby was promoted;
  * completion rate recovers to >= 90% of the baseline FPS after the
    eviction + promotion (recovery time is reported);
  * watchdog overhead within ``--bound`` + noise floor.

Soft checks (reported, never fatal): bounded p99 inflation vs baseline.

  PYTHONPATH=src python benchmarks/serve_chaos.py --smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp


def measure_single_replica_fps(cfg, params, bucket: int, n: int) -> float:
    """Closed-loop FPS of one replica (throwaway engine, outside any
    cluster metrics)."""
    from repro.serving.vision import VisionEngine, synth_requests

    eng = VisionEngine(cfg, params, batch_buckets=(bucket,), max_wait_s=0.0)
    eng.warmup()
    reqs = synth_requests(cfg, n, seed=99)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
        eng.step()
    eng.flush()
    return n / (time.perf_counter() - t0)


def run_offered_load(cluster, reqs, arrivals, deadline_s: float):
    """Open-loop phase: submit on the arrival schedule while pumping the
    cluster; returns (accounting dict, pump counts). Terminal deliveries
    are counted per uid through ``on_done`` — the zero-lost evidence."""
    from repro.serving.scheduler import Backpressure

    terminal = {}  # uid -> terminal callback count (must end at exactly 1)
    statuses = {}
    completions = []  # (t, status) for windowed-rate recovery analysis
    t0 = time.perf_counter()

    def done_cb(r):
        terminal[r.uid] = terminal.get(r.uid, 0) + 1
        statuses[r.uid] = r.status
        completions.append((time.perf_counter() - t0, r.status))

    accepted, shed = [], 0
    pumps = 0
    pumps_half = None
    i = 0
    while i < len(arrivals) or not cluster.idle:
        now = time.perf_counter() - t0
        if now > deadline_s:
            break  # wedged cluster: flush() below delivers terminals
        while i < len(arrivals) and arrivals[i] <= now:
            r = reqs[i]
            r.on_done = done_cb
            try:
                cluster.submit(r)
                accepted.append(r)
            except Backpressure:
                shed += 1
            i += 1
        cluster.step()
        pumps += 1
        if pumps_half is None and i >= len(arrivals) // 2:
            pumps_half = pumps
    cluster.flush()
    wall = time.perf_counter() - t0
    return {
        "accepted": len(accepted),
        "shed": shed,
        "terminal": terminal,
        "statuses": statuses,
        "completions": completions,
        "wall_s": wall,
        "t0": t0,
        "pumps": pumps,
        "pumps_half": pumps_half or max(1, pumps // 2),
    }


def recovery_time(completions, t_resume: float, target_fps: float,
                  window_s: float):
    """Earliest time after ``t_resume`` at which the completion rate over
    one sliding window reaches ``target_fps``; None when it never does."""
    times = sorted(t for t, status in completions if status == "completed")
    if not times:
        return None
    t = t_resume
    end = times[-1]
    a = np.asarray(times)
    while t <= end:
        n = int(np.searchsorted(a, t + window_s) - np.searchsorted(a, t))
        if n / window_s >= target_fps:
            return t - t_resume
        t += window_s / 4.0
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="m3vit-tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke config + short phases (CI)")
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--phase-s", type=float, default=0.0,
                    help="offered-load duration; 0 = 2.0s (smoke) / 5s")
    ap.add_argument("--bound", type=float, default=0.02,
                    help="max tolerated watchdog overhead beyond the "
                         "off/off2 noise floor")
    ap.add_argument("--repeats", type=int, default=0,
                    help="overhead rounds; 0 = 6 (smoke) / 10")
    ap.add_argument("--recovery-frac", type=float, default=0.9,
                    help="fraction of baseline FPS the chaos run must "
                         "recover to after the eviction")
    args = ap.parse_args()

    import jax

    import repro.models as M
    from repro.configs import PAPER_ARCHS, smoke_config
    from repro.configs.base import FaultConfig
    from repro.serving.cluster import ServingCluster
    from repro.serving.events import EventLog
    from repro.serving.vision import synth_requests

    if args.smoke:
        cfg = smoke_config(args.arch).replace(remat=False)
        bucket, est_n = 2, 16
    else:
        cfg = PAPER_ARCHS[args.arch].replace(remat=False)
        bucket, est_n = 4, 64
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    phase_s = args.phase_s or (2.0 if args.smoke else 5.0)
    repeats = args.repeats or (6 if args.smoke else 10)

    cap_fps = measure_single_replica_fps(cfg, params, bucket, est_n)
    # two active replicas on shared CPU: offer below ONE replica's measured
    # closed-loop capacity so the post-eviction survivor can absorb the
    # re-dispatched backlog and the recovery check measures the fault path,
    # not a CPU saturation artifact
    rate = 0.6 * cap_fps
    print(f"arch={cfg.name} devices={jax.device_count()} "
          f"single-replica capacity ~{cap_fps:.1f} FPS, "
          f"offered {rate:.1f} rps for {phase_s:.1f}s")

    arrivals = [i / rate for i in range(int(phase_s * rate))]
    deadline_s = max(10.0, 6 * phase_s)

    def cluster_for(faults, events=None):
        c = ServingCluster(
            cfg, params, replicas=2, standby=1,
            batch_buckets=(1, bucket), max_wait_s=1e-3,
            max_pending=4096, max_pending_per_replica=8 * bucket,
            clock=time.perf_counter, faults=faults, events=events,
        )
        c.warmup()
        return c

    # -- phase 1: no-fault baseline ------------------------------------------
    base_cluster = cluster_for(FaultConfig())
    base = run_offered_load(
        base_cluster,
        synth_requests(cfg, len(arrivals), seed=0), arrivals, deadline_s)
    base_completed = sum(
        1 for s in base["statuses"].values() if s == "completed")
    fps_base = base_completed / base["wall_s"]
    base_p99 = base_cluster.metrics.snapshot()[
        "aggregate"]["latency_ms"]["p99"]

    # -- phase 2: chaos — scheduled replica kill at steady state -------------
    # the kill step is calibrated from the baseline pump count: ordinal 0
    # dies when it has been ticked as many times as it took the baseline
    # to admit half its arrivals, which lands the crash mid-load
    kill_step = base["pumps_half"]
    chaos_faults = FaultConfig(
        inject=True, seed=0, error_budget=2,
        kill_schedule=((0, kill_step, "dead"),))
    events = EventLog(clock=time.perf_counter)
    chaos_cluster = cluster_for(chaos_faults, events=events)
    chaos = run_offered_load(
        chaos_cluster,
        synth_requests(cfg, len(arrivals), seed=1), arrivals, deadline_s)
    chaos_completed = sum(
        1 for s in chaos["statuses"].values() if s == "completed")
    chaos_failed = sum(
        1 for s in chaos["statuses"].values() if s == "failed")

    counters = chaos_cluster.metrics.snapshot()["aggregate"]["counters"]
    evicted_evs = events.events("replica_evicted")
    replaced_evs = events.events("replica_replaced")
    # recovery: windowed completion rate back at >= recovery_frac x the
    # baseline FPS, measured from the standby promotion
    window_s = max(0.25, 8.0 / max(fps_base, 1e-9))
    t_resume = ((replaced_evs[0]["t"] - chaos["t0"]) if replaced_evs
                else 0.0)
    rec_s = recovery_time(chaos["completions"], max(0.0, t_resume),
                          args.recovery_frac * fps_base, window_s)

    exactly_once = all(n == 1 for n in chaos["terminal"].values())
    zero_lost = (len(chaos["terminal"]) == chaos["accepted"]
                 and exactly_once)

    # -- phase 3: watchdog overhead (off / on / off2) ------------------------
    def overhead_cluster(watchdog: bool):
        return cluster_for(FaultConfig(watchdog=watchdog))

    clusters = {"off": overhead_cluster(False),
                "on": overhead_cluster(True),
                "off2": overhead_cluster(False)}
    n_over = est_n * 2
    uid0 = [10_000]

    def make():
        reqs = synth_requests(cfg, n_over, seed=7)
        for r in reqs:
            r.uid = uid0[0]
            uid0[0] += 1
        return reqs

    def serve_once(c):
        reqs = make()
        t0 = time.perf_counter()
        for r in reqs:
            c.submit(r)
            c.step()
        c.flush()
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        return dt

    for c in clusters.values():
        serve_once(c)  # untimed: residual compiles/caches land here
    dts = {name: [] for name in clusters}
    order = list(clusters)
    for r in range(repeats):
        # rotate in-round order so machine drift spreads over all variants
        for name in order[r % 3:] + order[:r % 3]:
            dts[name].append(serve_once(clusters[name]))
    overhead_on = float(np.median(
        [on / (0.5 * (a + b)) for on, a, b
         in zip(dts["on"], dts["off"], dts["off2"])])) - 1.0
    noise_floor = abs(float(np.median(
        [a / b for a, b in zip(dts["off"], dts["off2"])])) - 1.0)
    effective_bound = args.bound + noise_floor

    # p99s from the pooled cluster distributions (milliseconds)
    chaos_p99 = chaos_cluster.metrics.snapshot()[
        "aggregate"]["latency_ms"]["p99"]

    hard_checks = {
        "zero_lost_accepted": zero_lost,
        "exactly_once_terminal": exactly_once,
        "replica_evicted": len(evicted_evs) >= 1
        and counters.get("replicas_evicted", 0) >= 1,
        "standby_promoted": len(replaced_evs) >= 1
        and counters.get("replicas_replaced", 0) >= 1,
        "recovered_to_target_fps": rec_s is not None,
        "overhead_within_bound": overhead_on <= effective_bound,
    }
    soft_checks = {
        "redispatch_exercised": counters.get("cluster_redispatched", 0) >= 1,
        "no_terminal_failures": chaos_failed == 0,
        "baseline_all_completed": base_completed == base["accepted"],
        # injected-fault p99 inflation stays bounded: generous 10x because
        # a re-dispatched request legitimately pays queue wait twice and a
        # shared-CPU runner adds noise on top
        "p99_inflation_bounded": (
            not (base_p99 == base_p99 and chaos_p99 == chaos_p99)
            or chaos_p99 <= 10.0 * max(base_p99, 1.0)),
    }
    for name, ok in hard_checks.items():
        print(f"  [{'ok' if ok else 'MISS'}] {name}")
    for name, ok in soft_checks.items():
        print(f"  [{'ok' if ok else 'soft-miss'}] {name} (soft)")
    print(f"baseline: {base_completed}/{base['accepted']} completed, "
          f"{fps_base:.1f} FPS")
    print(f"chaos: {chaos_completed} completed / {chaos_failed} failed "
          f"of {chaos['accepted']} accepted; "
          f"evictions={counters.get('replicas_evicted', 0)} "
          f"redispatched={counters.get('cluster_redispatched', 0)} "
          f"duplicates={counters.get('duplicate_retirements', 0)}; "
          f"recovery "
          f"{('%.2fs' % rec_s) if rec_s is not None else 'NOT REACHED'} "
          f"after promotion (window {window_s:.2f}s)")
    print(f"overhead: watchdog {100 * overhead_on:+.2f}% "
          f"(noise floor {100 * noise_floor:.2f}%, "
          f"bound {100 * args.bound:.0f}% + floor)")

    report = {
        "meta": {
            "bench": "serve_chaos",
            "mode": "smoke" if args.smoke else "full",
            "arch": cfg.name,
            "devices": jax.device_count(),
            "offered_rps": rate,
            "phase_s": phase_s,
            "kill_step": kill_step,
            "repeats": repeats,
            "bound": args.bound,
            "recovery_frac": args.recovery_frac,
            "note": ("CPU-host run: replicas share compute; the run "
                     "measures the fault path's bookkeeping, not hardware "
                     "failover speed"),
        },
        "baseline": {
            "accepted": base["accepted"],
            "completed": base_completed,
            "shed": base["shed"],
            "fps": fps_base,
            "wall_s": base["wall_s"],
            "p99_ms": base_p99,
        },
        "chaos": {
            "accepted": chaos["accepted"],
            "completed": chaos_completed,
            "failed": chaos_failed,
            "shed": chaos["shed"],
            "wall_s": chaos["wall_s"],
            "p99_ms": chaos_p99,
            "recovery_s": rec_s,
            "recovery_window_s": window_s,
            "counters": {k: counters.get(k, 0) for k in (
                "replicas_evicted", "replicas_replaced",
                "cluster_redispatched", "cluster_failed",
                "duplicate_retirements", "replica_step_errors",
                "cluster_shed")},
            "eviction_events": evicted_evs,
            "replacement_events": replaced_evs,
        },
        "overhead": {
            "watchdog": overhead_on,
            "noise_floor": noise_floor,
            "effective_bound": effective_bound,
            "rounds": {name: ds for name, ds in dts.items()},
        },
        "checks": hard_checks,
        "soft_checks": soft_checks,
        "fps": fps_base,
    }
    stamp(report, "serve_chaos")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if not all(hard_checks.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
