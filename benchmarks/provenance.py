"""Provenance stamping for BENCH_* artifacts.

Every benchmark JSON gets a ``provenance`` block — schema version, git
SHA, timestamp, device kind/count, backend versions — so two artifacts
can be matched (same schema + device kind) and diffed (tools/bench_diff.py)
across CI runs. Without it the BENCH trajectory is a pile of uncomparable
numbers, which is why it sat empty through PR 7.

Import works both ways benchmarks run: as a script sibling
(``from provenance import stamp``) and as a namespace package from the
repo root (``from benchmarks.provenance import stamp``).
"""
from __future__ import annotations

import datetime
import os
import subprocess
import time

# Bump when a benchmark's report layout changes incompatibly; bench_diff
# refuses to compare artifacts across schema versions.
SCHEMA_VERSION = 1


def git_sha() -> str:
    """Current commit SHA: git first, CI env fallback, else "unknown"."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except Exception:
        pass
    return os.environ.get("GITHUB_SHA", "unknown")


def _device_info() -> dict:
    try:
        import jax

        devs = jax.devices()
        return {
            "backend": jax.default_backend(),
            "device_kind": devs[0].device_kind if devs else "unknown",
            "device_count": len(devs),
            "jax_version": jax.__version__,
        }
    except Exception:
        return {"backend": "unknown", "device_kind": "unknown",
                "device_count": 0, "jax_version": "unknown"}


def provenance(bench: str, schema: int = SCHEMA_VERSION) -> dict:
    """The provenance block for one benchmark artifact."""
    now = time.time()
    block = {
        "bench": bench,
        "schema_version": schema,
        "git_sha": git_sha(),
        "timestamp": now,
        "timestamp_iso": datetime.datetime.fromtimestamp(
            now, datetime.timezone.utc).isoformat(),
    }
    block.update(_device_info())
    env = {k: os.environ[k] for k in
           ("REPRO_PALLAS", "JAX_PLATFORMS", "XLA_FLAGS")
           if k in os.environ}
    if env:
        block["env"] = env
    return block


def stamp(report: dict, bench: str, schema: int = SCHEMA_VERSION) -> dict:
    """Attach the provenance block to a report (in place, and returned)."""
    report["provenance"] = provenance(bench, schema)
    return report
