"""Measured cluster FPS vs device count (the scaling counterpart of
``serve_vision_fps.py``; DESIGN.md section 7).

Drives the full multi-replica request path — cluster admission front-end,
least-loaded routing, per-replica dynamic batching, merged metrics — at
1/2/4/8 devices, fp32 vs materialized-int8, and writes
``BENCH_cluster.json``.

Device counts are faked on CPU with
``--xla_force_host_platform_device_count=N``. That flag must be set before
jax initializes, so the parent process re-executes this script as one
**worker subprocess per device count** (each with its own ``XLA_FLAGS``)
and merges the row JSON each worker prints on its last stdout line.

At the largest device count an additional expert-parallel row runs the
int8 tree with expert stacks sharded over all devices (DP replicas
elsewhere; EP within one replica here) — the two orchestration modes the
cluster composes.

  PYTHONPATH=src python benchmarks/serve_cluster_scaling.py --smoke
  PYTHONPATH=src python benchmarks/serve_cluster_scaling.py --devices 1 2 4 8
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp

DEVICE_FLAG = "--xla_force_host_platform_device_count"


# ---------------------------------------------------------------------------
# Worker: one device count, all variants (runs in its own process)
# ---------------------------------------------------------------------------

def _build_variants(cfg):
    import jax

    import repro.models as M
    from repro.configs import get_shape
    from repro.core.quant.ptq import (
        calibrate_model,
        ptq_model,
        quantized_config,
    )

    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    calib = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
             for i in range(2)]
    taps = calibrate_model(cfg, params, calib)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    return [("fp32", cfg, params), ("int8", quantized_config(cfg), p_int8)]


def _run_cluster(cfg, params, *, replicas, bucket, n_images, seed=0,
                 label="", mode="dp"):
    import time as _t

    from repro.serving.cluster import ServingCluster
    from repro.serving.vision import synth_requests

    cluster = ServingCluster(
        cfg, params, replicas=replicas, batch_buckets=(bucket,),
        max_wait_s=0.0, max_pending=0, max_pending_per_replica=0,
    )
    cluster.warmup()
    reqs = synth_requests(cfg, n_images, seed=seed)
    t0 = _t.perf_counter()
    for r in reqs:
        cluster.submit(r)
        cluster.step()
    cluster.flush()
    wall = _t.perf_counter() - t0
    assert all(r.done for r in reqs)
    agg = cluster.metrics.snapshot()["aggregate"]
    return {
        "variant": label,
        "mode": mode,
        "replicas": cluster.num_replicas,
        "bucket": bucket,
        "images": n_images,
        "wall_s": wall,
        "fps": n_images / wall,
        "latency_ms": agg["latency_ms"],
        "counters": agg["counters"],
        "expert_occupancy": agg["expert_occupancy"],
    }


def worker(args) -> None:
    import dataclasses

    import jax

    from repro.configs import PAPER_ARCHS, smoke_config

    if args.smoke:
        cfg = smoke_config(args.arch).replace(remat=False)
        n_images = args.images or 16
        bucket = 2
    else:
        cfg = PAPER_ARCHS[args.arch].replace(remat=False)
        n_images = args.images or 64
        bucket = 4

    n_dev = jax.device_count()
    rows = []
    for label, vcfg, vparams in _build_variants(cfg):
        row = _run_cluster(vcfg, vparams, replicas=n_dev, bucket=bucket,
                           n_images=n_images, label=label, mode="dp")
        row["devices"] = n_dev
        rows.append(row)
        if args.ep and label == "int8" and n_dev > 1 \
                and vcfg.moe is not None \
                and vcfg.moe.num_experts % n_dev == 0:
            ep_cfg = vcfg.replace(moe=dataclasses.replace(
                vcfg.moe, moe_exec="expert_parallel"))
            row = _run_cluster(ep_cfg, vparams, replicas=1, bucket=bucket,
                               n_images=n_images, label=label,
                               mode="expert_parallel")
            row["devices"] = n_dev
            rows.append(row)
    # last line of stdout is the parent's contract
    print(json.dumps({"devices": n_dev, "rows": rows}))


# ---------------------------------------------------------------------------
# Parent: one subprocess per device count, merged report
# ---------------------------------------------------------------------------

def _worker_env(n_devices: int) -> dict:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = re.sub(rf"{DEVICE_FLAG}=\S+", "", flags).strip()
    env["XLA_FLAGS"] = f"{flags} {DEVICE_FLAG}={n_devices}".strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.setdefault("REPRO_PALLAS", "ref")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="m3vit-tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke config + tiny image count (CI)")
    ap.add_argument("--images", type=int, default=0)
    ap.add_argument("--devices", type=int, nargs="*", default=[1, 2, 4, 8])
    ap.add_argument("--out", default="BENCH_cluster.json")
    ap.add_argument("--ep", dest="ep", action="store_true", default=True,
                    help="add an expert-parallel int8 row per multi-device "
                         "count (default on)")
    ap.add_argument("--no-ep", dest="ep", action="store_false")
    ap.add_argument("--worker", action="store_true",
                    help="internal: run one device count in-process")
    args = ap.parse_args()

    if args.worker:
        worker(args)
        return

    rows = []
    t_start = time.perf_counter()
    for n in args.devices:
        cmd = [sys.executable, os.path.abspath(__file__), "--worker",
               "--arch", args.arch]
        if args.smoke:
            cmd.append("--smoke")
        if args.images:
            cmd += ["--images", str(args.images)]
        if not args.ep:
            cmd.append("--no-ep")
        proc = subprocess.run(cmd, env=_worker_env(n), capture_output=True,
                              text=True)
        if proc.returncode != 0:
            sys.stderr.write(proc.stdout + proc.stderr)
            raise RuntimeError(f"worker for {n} devices failed")
        payload = json.loads(proc.stdout.strip().splitlines()[-1])
        for row in payload["rows"]:
            rows.append(row)
            print(f"devices={row['devices']} {row['variant']:5s} "
                  f"{row['mode']:15s} replicas={row['replicas']}: "
                  f"{row['fps']:8.1f} FPS  "
                  f"p50={row['latency_ms']['p50']:.1f}ms "
                  f"p99={row['latency_ms']['p99']:.1f}ms")

    report = {
        "meta": {
            "bench": "serve_cluster_scaling",
            "mode": "smoke" if args.smoke else "full",
            "arch": args.arch,
            "device_counts": args.devices,
            "wall_s": time.perf_counter() - t_start,
            "note": ("CPU host devices faked with "
                     f"{DEVICE_FLAG}; FPS scaling is scheduling-real but "
                     "compute shares one CPU — device-count trends, not "
                     "absolute throughput"),
        },
        "rows": rows,
    }
    if args.out:
        stamp(report, "serve_cluster_scaling")
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
