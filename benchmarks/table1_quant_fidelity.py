"""Paper Table 1 proxy: quantization fidelity on the paper's architectures.

ImageNet is not available in-container, so the claim "<=1% top-1 loss at
W8/A8/Attn4" is evaluated as a *fidelity proxy*: train each arch briefly on
the deterministic synthetic classification task (so logits carry real
decision structure), run the full CoQMoE PTQ pipeline (calibrate ->
reparam -> quantize), then report:

  * top-1 agreement between FP and quantized predictions (proxy for
    accuracy drop: 1 - agreement upper-bounds the accuracy change), and
  * logit SQNR in dB,

for BOTH quantized executions: the fake-quant simulation
(``ptq_model(materialize="fake")``) and the *materialized int8* path
(``materialize="int8"``) that serving actually ships — stored-int8 weights
executed through the int8 kernels (DESIGN.md section 4). The two columns
must track each other to accumulation rounding; the int8 column is the one
that covers the deployed format.

Also reports the ablation the paper's section 3 implies: MinMax per-layer
symmetric WITHOUT the reparameterization (the Table-1 MinMax row that
collapses) vs the reparam path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import PAPER_ARCHS, get_shape
from repro.core.quant.calibrate import TapCollector
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.data import SyntheticPipeline
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig

# reduced-size twins of the paper archs (CPU-trainable in minutes) — the
# quantizer math is dimension-independent; full-dim forward numbers come
# from the dry-run/roofline path.
BENCH_ARCHS = ["vit-tiny", "m3vit-tiny"]
FULL_FWD_ARCHS = ["vit-tiny", "vit-small", "vit-base", "deit-tiny",
                  "m3vit-tiny", "m3vit-small"]


def _train_briefly(cfg, steps=60, batch=16):
    shape = get_shape("train_4k").replace(global_batch=batch)
    tc = TrainerConfig(total_steps=steps, lr=1e-3, warmup_steps=5,
                       log_every=10_000)
    tr = Trainer(cfg, shape, make_host_mesh(), tc)
    state = tr.run()
    return state.params, shape


def _fidelity(cfg, params, shape, n_eval=4, minmax_baseline=False,
              with_int8=True):
    """Returns (fake_agree, fake_sqnr, int8_agree, int8_sqnr); the int8
    entries are None when with_int8=False (ablation rows skip the
    materialized tree — its results would be discarded)."""
    pipe = SyntheticPipeline(cfg, shape, seed=123)
    calib = [
        {k: jnp.asarray(v) for k, v in pipe.batch_for_step(s).items()}
        for s in range(2)  # the paper calibrates from 32 images; 2x16 = 32
    ]
    taps = calibrate_model(cfg, params, calib)
    if minmax_baseline:
        # Ablation: skip the reparam — plain per-layer MinMax symmetric.
        # Collapse the per-channel stats to per-tensor (what MinMax does).
        for site, st in taps.stats.items():
            st["min"] = np.full_like(st["min"], st["min"].min())
            st["max"] = np.full_like(st["max"], st["max"].max())
    trees = {"fake": ptq_model(cfg, params, taps)}
    if with_int8:
        trees["int8"] = ptq_model(cfg, params, taps, materialize="int8")
    qcfg = quantized_config(cfg)
    agree = {k: [] for k in trees}
    sqnr_num = {k: 0.0 for k in trees}
    sqnr_den = {k: 0.0 for k in trees}
    for s in range(100, 100 + n_eval):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_for_step(s).items()}
        lg_fp, _ = M.forward(params, cfg, batch)
        for key, p_q in trees.items():
            lg_q, _ = M.forward(p_q, qcfg, batch)
            agree[key].append(np.mean(np.asarray(jnp.argmax(lg_fp, -1) ==
                                                 jnp.argmax(lg_q, -1))))
            sqnr_num[key] += float(jnp.sum(lg_fp.astype(jnp.float64) ** 2))
            sqnr_den[key] += float(
                jnp.sum((lg_fp - lg_q).astype(jnp.float64) ** 2))
    sqnr = {
        k: 10 * np.log10(sqnr_num[k] / max(sqnr_den[k], 1e-30))
        for k in trees
    }
    return (float(np.mean(agree["fake"])), sqnr["fake"],
            float(np.mean(agree["int8"])) if with_int8 else None,
            sqnr.get("int8"))


def run(csv=False, train_steps=60):
    from repro.configs import smoke_config

    rows = []
    for arch in BENCH_ARCHS:
        cfg = PAPER_ARCHS[arch].replace(remat=False)
        # reduce depth for CPU training speed, keep layer dims authentic
        cfg = cfg.replace(num_layers=4)
        t0 = time.perf_counter()
        params, shape = _train_briefly(cfg, steps=train_steps)
        eval_shape = shape
        agree, sqnr, agree_i8, sqnr_i8 = _fidelity(cfg, params, eval_shape)
        agree_mm, sqnr_mm, _, _ = _fidelity(cfg, params, eval_shape,
                                            minmax_baseline=True,
                                            with_int8=False)
        dt = time.perf_counter() - t0
        rows.append({
            "arch": arch, "top1_agreement": agree, "logit_sqnr_db": sqnr,
            "int8_agreement": agree_i8, "int8_sqnr_db": sqnr_i8,
            "minmax_agreement": agree_mm, "minmax_sqnr_db": sqnr_mm,
            "seconds": dt,
        })
    if csv:
        for r in rows:
            print(f"table1_{r['arch']},{r['seconds']*1e6:.0f},"
                  f"agree={r['top1_agreement']:.4f};sqnr={r['logit_sqnr_db']:.1f}dB;"
                  f"int8_agree={r['int8_agreement']:.4f};"
                  f"int8_sqnr={r['int8_sqnr_db']:.1f}dB;"
                  f"minmax_agree={r['minmax_agreement']:.4f}")
    else:
        print(f"{'arch':14s} {'fake agree':>10s} {'fake dB':>8s} "
              f"{'int8 agree':>10s} {'int8 dB':>8s} "
              f"{'MinMax agree':>12s} {'MinMax dB':>9s}")
        for r in rows:
            print(f"{r['arch']:14s} {r['top1_agreement']:10.4f} "
                  f"{r['logit_sqnr_db']:8.1f} {r['int8_agreement']:10.4f} "
                  f"{r['int8_sqnr_db']:8.1f} {r['minmax_agreement']:12.4f} "
                  f"{r['minmax_sqnr_db']:9.1f}")
        print("\npaper Table 1 (full ImageNet, for reference): "
              "M3ViT 85.17 -> 84.89 (-0.28%), ViT-B 84.53 -> 83.99 @ 8/8/4")
    return rows


if __name__ == "__main__":
    run()
