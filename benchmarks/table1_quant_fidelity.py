"""Paper Table 1 proxy: quantization fidelity on the paper's architectures.

ImageNet is not available in-container, so the claim "<=1% top-1 loss at
W8/A8/Attn4" is evaluated as a *fidelity proxy*: train each arch briefly on
the deterministic synthetic classification task (so logits carry real
decision structure), run the full CoQMoE PTQ pipeline (calibrate ->
reparam -> quantize), then report:

  * top-1 agreement between FP and quantized predictions (proxy for
    accuracy drop: 1 - agreement upper-bounds the accuracy change), and
  * logit SQNR in dB,

for BOTH quantized executions: the fake-quant simulation
(``ptq_model(materialize="fake")``) and the *materialized int8* path
(``materialize="int8"``) that serving actually ships — stored-int8 weights
executed through the int8 kernels (DESIGN.md section 4). The two columns
must track each other to accumulation rounding; the int8 column is the one
that covers the deployed format.

Also reports the ablation the paper's section 3 implies: MinMax per-layer
symmetric WITHOUT the reparameterization (the Table-1 MinMax row that
collapses) vs the reparam path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import PAPER_ARCHS, get_shape
from repro.core.quant.calibrate import TapCollector
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.data import SyntheticPipeline
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig

# reduced-size twins of the paper archs (CPU-trainable in minutes) — the
# quantizer math is dimension-independent; full-dim forward numbers come
# from the dry-run/roofline path.
BENCH_ARCHS = ["vit-tiny", "m3vit-tiny"]
FULL_FWD_ARCHS = ["vit-tiny", "vit-small", "vit-base", "deit-tiny",
                  "m3vit-tiny", "m3vit-small"]


def _train_briefly(cfg, steps=60, batch=16):
    shape = get_shape("train_4k").replace(global_batch=batch)
    tc = TrainerConfig(total_steps=steps, lr=1e-3, warmup_steps=5,
                       log_every=10_000)
    tr = Trainer(cfg, shape, make_host_mesh(), tc)
    state = tr.run()
    return state.params, shape


def _fidelity(cfg, params, shape, n_eval=4, minmax_baseline=False,
              with_int8=True):
    """Returns {"fake"|"int8"|"int4": (agree, sqnr_db)}. The int8/int4
    entries are skipped when with_int8=False (ablation rows skip the
    materialized trees — their results would be discarded); int4 is also
    skipped for dense archs (no MoE expert stack to pack)."""
    pipe = SyntheticPipeline(cfg, shape, seed=123)
    calib = [
        {k: jnp.asarray(v) for k, v in pipe.batch_for_step(s).items()}
        for s in range(2)  # the paper calibrates from 32 images; 2x16 = 32
    ]
    taps = calibrate_model(cfg, params, calib)
    if minmax_baseline:
        # Ablation: skip the reparam — plain per-layer MinMax symmetric.
        # Collapse the per-channel stats to per-tensor (what MinMax does).
        for site, st in taps.stats.items():
            st["min"] = np.full_like(st["min"], st["min"].min())
            st["max"] = np.full_like(st["max"], st["max"].max())
    trees = {"fake": ptq_model(cfg, params, taps)}
    if with_int8:
        trees["int8"] = ptq_model(cfg, params, taps, materialize="int8")
        if cfg.moe is not None:
            # experts-only default scheme: packed int4 stacks, rest int8
            trees["int4"] = ptq_model(cfg, params, taps, materialize="int4")
    qcfg = quantized_config(cfg)
    agree = {k: [] for k in trees}
    sqnr_num = {k: 0.0 for k in trees}
    sqnr_den = {k: 0.0 for k in trees}
    for s in range(100, 100 + n_eval):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_for_step(s).items()}
        lg_fp, _ = M.forward(params, cfg, batch)
        for key, p_q in trees.items():
            lg_q, _ = M.forward(p_q, qcfg, batch)
            agree[key].append(np.mean(np.asarray(jnp.argmax(lg_fp, -1) ==
                                                 jnp.argmax(lg_q, -1))))
            sqnr_num[key] += float(jnp.sum(lg_fp.astype(jnp.float64) ** 2))
            sqnr_den[key] += float(
                jnp.sum((lg_fp - lg_q).astype(jnp.float64) ** 2))
    return {
        k: (float(np.mean(agree[k])),
            10 * np.log10(sqnr_num[k] / max(sqnr_den[k], 1e-30)))
        for k in trees
    }


def run(csv=False, train_steps=60, archs=None, n_eval=4):
    rows = []
    for arch in archs or BENCH_ARCHS:
        cfg = PAPER_ARCHS[arch].replace(remat=False)
        # reduce depth for CPU training speed, keep layer dims authentic
        cfg = cfg.replace(num_layers=4)
        t0 = time.perf_counter()
        params, shape = _train_briefly(cfg, steps=train_steps)
        eval_shape = shape
        fid = _fidelity(cfg, params, eval_shape, n_eval=n_eval)
        fid_mm = _fidelity(cfg, params, eval_shape, n_eval=n_eval,
                           minmax_baseline=True, with_int8=False)
        dt = time.perf_counter() - t0
        agree_i4, sqnr_i4 = fid.get("int4", (None, None))
        rows.append({
            "arch": arch,
            "top1_agreement": fid["fake"][0],
            "logit_sqnr_db": fid["fake"][1],
            "int8_agreement": fid["int8"][0],
            "int8_sqnr_db": fid["int8"][1],
            # int4 column: experts-only packed int4 (None for dense archs)
            "int4_agreement": agree_i4, "int4_sqnr_db": sqnr_i4,
            "minmax_agreement": fid_mm["fake"][0],
            "minmax_sqnr_db": fid_mm["fake"][1],
            "seconds": dt,
        })
    if csv:
        for r in rows:
            i4 = ("" if r["int4_agreement"] is None else
                  f"int4_agree={r['int4_agreement']:.4f};")
            print(f"table1_{r['arch']},{r['seconds']*1e6:.0f},"
                  f"agree={r['top1_agreement']:.4f};sqnr={r['logit_sqnr_db']:.1f}dB;"
                  f"int8_agree={r['int8_agreement']:.4f};"
                  f"int8_sqnr={r['int8_sqnr_db']:.1f}dB;{i4}"
                  f"minmax_agree={r['minmax_agreement']:.4f}")
    else:
        print(f"{'arch':14s} {'fake agree':>10s} {'fake dB':>8s} "
              f"{'int8 agree':>10s} {'int8 dB':>8s} "
              f"{'int4 agree':>10s} {'int4 dB':>8s} "
              f"{'MinMax agree':>12s} {'MinMax dB':>9s}")
        for r in rows:
            i4a = ("       n/a" if r["int4_agreement"] is None
                   else f"{r['int4_agreement']:10.4f}")
            i4s = ("     n/a" if r["int4_sqnr_db"] is None
                   else f"{r['int4_sqnr_db']:8.1f}")
            print(f"{r['arch']:14s} {r['top1_agreement']:10.4f} "
                  f"{r['logit_sqnr_db']:8.1f} {r['int8_agreement']:10.4f} "
                  f"{r['int8_sqnr_db']:8.1f} {i4a} {i4s} "
                  f"{r['minmax_agreement']:12.4f} "
                  f"{r['minmax_sqnr_db']:9.1f}")
        print("\npaper Table 1 (full ImageNet, for reference): "
              "M3ViT 85.17 -> 84.89 (-0.28%), ViT-B 84.53 -> 83.99 @ 8/8/4")
    return rows


def main() -> None:
    import argparse
    import json
    import sys

    try:  # script sibling vs repo-root namespace import
        from benchmarks.provenance import stamp
    except ImportError:
        from provenance import stamp

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one MoE arch, short train/eval (CI)")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here (BENCH_table1.json)")
    args = ap.parse_args()

    archs = ["m3vit-tiny"] if args.smoke else None
    steps = min(args.train_steps, 20) if args.smoke else args.train_steps
    rows = run(csv=args.csv, train_steps=steps, archs=archs,
               n_eval=2 if args.smoke else 4)
    # acceptance: int4 top-1 within 1% of int8 on every MoE arch evaluated
    gaps = [r["int8_agreement"] - r["int4_agreement"]
            for r in rows if r["int4_agreement"] is not None]
    ok = all(g <= 0.01 for g in gaps)
    if args.out:
        out = {
            "benchmark": "table1_quant_fidelity",
            "mode": "smoke" if args.smoke else "full",
            "train_steps": steps,
            "rows": rows,
            "int4_within_1pct_of_int8": ok,
        }
        with open(args.out, "w") as f:
            json.dump(stamp(out, "table1_quant_fidelity"), f, indent=1)
        print(f"wrote {args.out}: {len(rows)} archs, "
              f"int4_within_1pct_of_int8={ok}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
