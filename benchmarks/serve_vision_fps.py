"""Measured end-to-end vision-serving FPS (paper Tables 3/4 counterpart).

Unlike ``table34_throughput.py`` (analytic roofline projection + bare jitted
forward), this drives the full request path — scheduler, padded bucket
batches, double-buffered dispatch, top-k responses — through ``VisionEngine``
and reports *measured* frames/second, putting a real number next to the
paper's ~155 FPS row.

Sweeps: fp32 vs materialized-int8 ``QuantizedParams`` (the stored-int8
weights execute through the int8 kernels; no fp expert copy), across batch
buckets (closed loop: everything queued up front, full batches form) and —
in full mode — offered load (open loop: paced arrivals at fractions of the
measured closed-loop capacity, latency under load).

Writes ``BENCH_serving.json`` (schema in DESIGN.md section 6).

  PYTHONPATH=src python benchmarks/serve_vision_fps.py --smoke
  PYTHONPATH=src python benchmarks/serve_vision_fps.py --arch m3vit-tiny
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro.models as M
from repro.configs import PAPER_ARCHS, get_shape, smoke_config
from repro.core.quant.ptq import calibrate_model, ptq_model, quantized_config
from repro.serving.vision import VisionEngine, synth_requests
try:  # script sibling vs repo-root namespace import
    from benchmarks.provenance import stamp
except ImportError:
    from provenance import stamp


def build_variants(cfg):
    """[(label, runtime cfg, params)] — fp32 and materialized int8."""
    params = M.init_model_params(cfg, jax.random.PRNGKey(0))
    shape = get_shape("train_4k").replace(seq_len=24, global_batch=2)
    calib = [M.synth_batch(cfg, shape, jax.random.PRNGKey(i))
             for i in range(2)]
    taps = calibrate_model(cfg, params, calib)
    p_int8 = ptq_model(cfg, params, taps, materialize="int8")
    return [("fp32", cfg, params), ("int8", quantized_config(cfg), p_int8)]


def run_closed_loop(cfg, params, *, bucket: int, n_images: int,
                    seed: int = 0) -> dict:
    """Everything queued up front: full batches form, maximum load."""
    eng = VisionEngine(cfg, params, batch_buckets=(bucket,), max_wait_s=0.0,
                       max_pending=0, top_k=5)
    eng.warmup()
    reqs = synth_requests(cfg, n_images, seed=seed)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.flush()
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    snap = eng.metrics.snapshot()
    return {
        "load": "closed",
        "bucket": bucket,
        "images": n_images,
        "wall_s": wall,
        "fps": n_images / wall,
        "latency_ms": snap["latency_ms"],
        "batch_latency_ms": snap["batch_latency_ms"],
        "counters": snap["counters"],
        "expert_occupancy": snap["expert_occupancy"],
    }


def run_offered_load(cfg, params, *, bucket: int, n_images: int,
                     rate_fps: float, max_wait_s: float,
                     seed: int = 0) -> dict:
    """Open loop: paced arrivals at ``rate_fps``; batches coalesce up to the
    deadline. Measures latency under load rather than peak throughput."""
    eng = VisionEngine(cfg, params, batch_buckets=(1, bucket),
                       max_wait_s=max_wait_s, max_pending=0, top_k=5)
    eng.warmup()
    reqs = synth_requests(cfg, n_images, seed=seed)
    period = 1.0 / rate_fps
    t0 = time.perf_counter()
    for i, r in enumerate(reqs):
        target = t0 + i * period
        while time.perf_counter() < target:
            eng.step()  # keep pumping while we wait for the next arrival
        eng.submit(r)
        eng.step()
    eng.flush()
    wall = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    return {
        "load": "open",
        "offered_fps": rate_fps,
        "bucket": bucket,
        "images": n_images,
        "wall_s": wall,
        "fps": n_images / wall,
        "latency_ms": snap["latency_ms"],
        "batch_latency_ms": snap["batch_latency_ms"],
        "counters": snap["counters"],
        "expert_occupancy": snap["expert_occupancy"],
    }


def run(arch: str = "m3vit-tiny", smoke: bool = False,
        n_images: int = 0, buckets=None, out: str = "BENCH_serving.json",
        csv: bool = False) -> dict:
    if smoke:
        cfg = smoke_config(arch).replace(remat=False)
        n_images = n_images or 24
        buckets = tuple(buckets or (1, 4))
    else:
        cfg = PAPER_ARCHS[arch].replace(remat=False)
        n_images = n_images or 64
        buckets = tuple(buckets or (1, 4, 8))

    rows = []
    for label, vcfg, vparams in build_variants(cfg):
        for b in buckets:
            row = run_closed_loop(vcfg, vparams, bucket=b,
                                  n_images=n_images)
            row.update(variant=label)
            rows.append(row)
            if csv:
                print(f"serve_vision_{label}_b{b},"
                      f"{row['wall_s']/n_images*1e6:.0f},"
                      f"fps={row['fps']:.1f}")
            else:
                print(f"{label:5s} bucket={b:2d} closed: "
                      f"{row['fps']:8.1f} FPS  "
                      f"p50={row['latency_ms']['p50']:.1f}ms "
                      f"p99={row['latency_ms']['p99']:.1f}ms")
        if not smoke:
            # offered-load sweep at the largest bucket: 50% / 90% of the
            # measured closed-loop capacity
            peak = max(r["fps"] for r in rows
                       if r["variant"] == label and r["load"] == "closed")
            batch_ms = rows[-1]["batch_latency_ms"]["p50"]
            wait = max(1e-3, batch_ms / 1e3)
            for frac in (0.5, 0.9):
                row = run_offered_load(
                    vcfg, vparams, bucket=buckets[-1], n_images=n_images,
                    rate_fps=max(1.0, frac * peak), max_wait_s=wait,
                )
                row.update(variant=label)
                rows.append(row)
                print(f"{label:5s} bucket={buckets[-1]:2d} open "
                      f"@{row['offered_fps']:6.1f}/s: "
                      f"{row['fps']:8.1f} FPS  "
                      f"p50={row['latency_ms']['p50']:.1f}ms "
                      f"p99={row['latency_ms']['p99']:.1f}ms")

    report = {
        "meta": {
            "bench": "serve_vision_fps",
            "mode": "smoke" if smoke else "full",
            "arch": cfg.name,
            "family": cfg.family,
            "backend": jax.default_backend(),
            "image_tokens": cfg.image_tokens,
            "num_classes": cfg.num_classes,
            "num_experts": cfg.moe.num_experts if cfg.moe else 0,
            "paper_row_fps": 155.0,  # CoQMoE-C on U280, paper Table 4
        },
        "rows": rows,
    }
    if out:
        stamp(report, "serve_vision_fps")
        with open(out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {out} ({len(rows)} rows)")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="m3vit-tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke config + tiny image count (CI)")
    ap.add_argument("--images", type=int, default=0)
    ap.add_argument("--buckets", type=int, nargs="*", default=None)
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    run(arch=args.arch, smoke=args.smoke, n_images=args.images,
        buckets=args.buckets, out=args.out, csv=args.csv)


if __name__ == "__main__":
    main()
