"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md section
Roofline).

Per (arch x shape) cell on the single-pod 16x16 mesh:

  compute term    = dot_FLOPs_per_device / peak_FLOP/s
  memory term     = HBM_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI_link_bw

(all per-device — the compiled module IS the per-device SPMD program, so
dividing global quantities by chip count is already done by GSPMD).

MODEL_FLOPS is the analytic minimum useful work:
  train:   6 * N_active * tokens  + attention term (10 * L * S^2 * d_attn *
           B / 2 causal; x5/6 of the 12x factor since remat recompute is
           NOT useful work)
  prefill: 2 * N_active * tokens + causal attention forward
  decode:  2 * N_active * B + B * L * S * d_attn * 4 / 2

The ratio MODEL_FLOPS / dot_FLOPs exposes remat/redundancy waste; the
dominant term names the bottleneck the perf loop attacks.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

from benchmarks import hw


def model_flops(cfg, shape, n_devices: int) -> float:
    """Analytic useful FLOPs per device for one step of this cell."""
    n_active = cfg.active_param_count()
    S = shape.seq_len
    B = shape.global_batch
    L = cfg.num_layers
    a = cfg.attn
    attn_fwd = 0.0
    if a is not None:
        d_attn = a.q_dim  # QK^T + PV: 2 * 2 * S^2 * H * hd (x1/2 causal)
        if cfg.shared_attn_every:
            L_attn = L // cfg.shared_attn_every
        elif cfg.family == "encdec":
            L_attn = cfg.encoder_layers + 2 * cfg.decoder_layers
        else:
            L_attn = L
        if shape.kind == "decode":
            attn_fwd = 2 * 2 * B * S * d_attn * L_attn  # 1 new q row
        else:
            eff_S = S
            attn_fwd = 2 * 2 * B * eff_S * eff_S * d_attn * L_attn / 2
    if shape.kind == "train":
        tokens = B * S
        total = 6 * n_active * tokens + 3 * attn_fwd
    elif shape.kind == "prefill":
        tokens = B * S
        total = 2 * n_active * tokens + attn_fwd
    else:  # decode: one token per sequence
        total = 2 * n_active * B + attn_fwd
    return total / n_devices


def roofline_row(rec: dict, cfg, shape) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = hw.CHIPS_MULTI_POD if rec["mesh"].startswith("pod2") \
        else hw.CHIPS_SINGLE_POD
    t_compute = rec["dot_flops_per_device"] / hw.PEAK_FLOPS_BF16
    # memory term: fusion-boundary bytes minus pure dtype-convert fusions
    # (XLA:CPU has no bf16 dot and materializes f32 weight copies that the
    # TPU MXU datapath absorbs — see benchmarks/hlo_analysis.py)
    hbm = rec["hbm_bytes_per_device"] - rec.get("convert_bytes_per_device", 0)
    t_memory = hbm / hw.HBM_BW
    t_coll = rec["collective_bytes_per_device"] / hw.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = model_flops(cfg, shape, chips)
    useful_ratio = mf / max(rec["dot_flops_per_device"], 1.0)
    # roofline fraction: useful FLOP/s achieved vs peak at the modeled time
    mfu = mf / max(step_time, 1e-12) / hw.PEAK_FLOPS_BF16
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_device": mf,
        "hlo_flops_per_device": rec["dot_flops_per_device"],
        "useful_ratio": useful_ratio,
        "roofline_fraction": mfu,
    }


def load_all(dryrun_dir="experiments/dryrun", mesh="16x16"):
    from repro.configs import get_config, get_shape

    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        if path.endswith("__q.json"):  # quantized variants live in §Perf
            continue
        rec = json.load(open(path))
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        row = roofline_row(rec, cfg, shape)
        if row:
            rows.append(row)
    return rows


def run(csv=False, mesh="16x16"):
    rows = load_all(mesh=mesh)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if csv:
        for r in rows:
            print(f"roofline_{r['arch']}_{r['shape']},0,"
                  f"dom={r['dominant']};frac={r['roofline_fraction']:.4f}")
    else:
        hdr = (f"{'arch':26s}{'shape':13s}{'compute_s':>10s}{'memory_s':>10s}"
               f"{'coll_s':>9s}  {'dominant':10s}{'useful':>7s}{'roofl%':>7s}")
        print(hdr)
        for r in rows:
            print(f"{r['arch']:26s}{r['shape']:13s}"
                  f"{r['t_compute_s']:10.4f}{r['t_memory_s']:10.4f}"
                  f"{r['t_collective_s']:9.4f}  {r['dominant']:10s}"
                  f"{r['useful_ratio']:7.2f}{100*r['roofline_fraction']:7.2f}")
    return rows


if __name__ == "__main__":
    import sys

    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "16x16")
